"""Round-loop bench: one fused ``lax.scan`` vs the eager per-round loop.
Writes ``BENCH_rounds.json``.

Sweeps clients ∈ {40, 400, 4000} of the StoCFL round (the paper's
synthetic MLP task, device arena + device partition + device sampling in
BOTH modes — the operands are identical, so the ratio isolates exactly
what ``engine.run_rounds`` removes: the per-round host dispatch,
trace-cache lookup and numpy cohort draw):

  eager   rounds × ``engine.run_round`` (device rng backend), timed per
          round after warm-up — the pre-scan steady state.
  scan    ``engine.run_rounds(state, R)`` — the whole span is one XLA
          program. The first call compiles; the compiled program is
          cached on the engine context (keyed by carry/operand shapes),
          so the steady-state number is a SECOND call through the same
          cache, and ``first_compile_s`` is reported separately (the
          honest one-time cost of fusing R rounds).

Both modes run the same key chain, so they execute the same cohorts on
the same data — the parity battery (tests/test_round_scan.py) asserts
the trajectories are bitwise equal; this bench only asks which one is
faster.

Every row now runs 20 rounds (the 4000-client row used to run 10,
which amortized the scan's fixed per-call cost differently from the
other rows) and the 4000-client row runs unchunked: at cohort = 200
the old ``cohort_chunk=64`` split the vmapped step into four
``lax.map`` chunks, which on this host is ~2× pure dispatch overhead
with no memory benefit at these shapes. Two extra 400-client rows
sweep the new feature axes — ``fused`` (flat prox-kernel inner step)
and ``dtype=bfloat16`` (bf16 params/grads, fp32 clustering) — against
the fp32/unfused baseline. Each row also reports
``warm_first_compile_s``: the first-call cost after
``jax.clear_caches()`` with the persistent compilation cache enabled,
i.e. the compile tax a fresh process actually pays once the cache
directory is warm (trace + deserialize instead of XLA compile).

Besides the timing sweep, ``--compile-sets`` measures the OTHER cost
the fused scan is designed to bound: the number of distinct XLA
programs compiled per strategy across a population-churn timeline
(cold start, then repeated join → train → leave → train cycles),
counted with ``repro.analysis.sanitize.compile_budget``.  The pow2
shape quantization (cohort pool / sizes / arena row map / Ditto
personal carry) pins the warm-cycle count to 0 for every strategy
except stocfl's host bank rebuild (data-dependent merge shapes — see
docs/ANALYSIS.md); the regression battery in
``tests/test_compile_budget.py`` gates exactly these numbers.

Every timing row carries a ``devices`` field (1 for the plain sweep).
``--mesh N`` reruns the smoke-sized points on an N-device client mesh
(``repro.launch.mesh.make_client_mesh``) and MERGES those rows into an
existing out file — the multi-device CI lane runs ``--mesh 1`` and
``--mesh 4`` on forced host devices, so the json grows a device-count
axis whose 1-device row should sit within noise of the unmeshed scan
(the mesh-1 program is bitwise-identical modulo sharding annotations;
see docs/SHARDING.md). Benches bypass tests/conftest.py, so forced
host devices come from the same env knob, read here before jax loads:

  REPRO_FORCE_HOST_DEVICES=8 PYTHONPATH=src \\
      python -m benchmarks.round_scan --mesh 4

``--async`` sweeps the asynchronous buffered round
(``engine.run_round_async``, docs/ASYNC.md) against the eager sync
round at the same shapes: rounds/sec vs ``staleness_cap`` with a
saturating per-cohort delay pattern (delays cycle 0..cap, so every
flush merges a full steady-state width through the staleness-weighted
path). Rows land in the same ``results`` list with ``mode: "async"``
and a ``staleness_cap`` field, merged by row key like ``--mesh``.

  PYTHONPATH=src python -m benchmarks.round_scan              # full sweep
  PYTHONPATH=src python -m benchmarks.round_scan --smoke      # CI-sized
  PYTHONPATH=src python -m benchmarks.round_scan --async [--smoke]
                         # async-vs-sync sweep; merges mode="async" rows
  PYTHONPATH=src python -m benchmarks.round_scan --compile-sets
                         # churn compile-count sweep only; merges the
                         # ``compile_sets`` section into an existing out file
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

# must land before jax initializes its backends (same knob tests/conftest.py
# translates for pytest runs)
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={int(_force)}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _federation(n_clients: int, n_per: int, seed: int = 0):
    clients, _, _ = rotated(n_clusters=4, n_clients=n_clients, n_per=n_per,
                            seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _cfg(sample_rate: float, chunk: int, fused: bool = False,
         dtype: str = "float32", async_cfg=None) -> engine.EngineConfig:
    return engine.EngineConfig(
        tau=0.5, lam=0.05, lr=0.1, local_steps=1, sample_rate=sample_rate,
        seed=0, project_dim=1024, cohort_chunk=chunk,
        cluster_backend="device", rng_backend="device",
        fused_step=fused, dtype=dtype, async_cfg=async_cfg)


def _row_key(r):
    """Identity of one timing row — --mesh and --async replace stale
    rows for the combos they re-measure and keep the rest of the sweep."""
    return (r["clients"], r["rounds"], r["sample_rate"], r["fused"],
            r["dtype"], r.get("devices", 1), r.get("mode", "sync"),
            r.get("staleness_cap", -1))


def _merge_rows(out: str, rows: list) -> None:
    try:
        with open(out) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = {"bench": "round_scan", "results": []}
    fresh = {_row_key(r) for r in rows}
    doc["results"] = [r for r in doc.get("results", [])
                      if _row_key(r) not in fresh] + rows
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)


def _init(clients, cfg, mesh=None):
    return engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                       clients, cfg, arena=True, mesh=mesh)


def _onboard(state, n_clients: int):
    """One full-participation round (observe every client, settle the
    partition) + a few sampled rounds so both modes start from the same
    settled federation."""
    state, _ = engine.run_round(state, np.arange(n_clients))
    for _ in range(3):
        state, _ = engine.run_round(state)
    return state


def run_point(n_clients: int, rounds: int, sample_rate: float,
              chunk: int, n_per: int, fused: bool = False,
              dtype: str = "float32", warm: bool = False,
              mesh=None) -> dict:
    clients = _federation(n_clients, n_per)
    cfg = _cfg(sample_rate, chunk, fused, dtype)

    # both steady-state columns are min-of-3 spans: host noise (GC,
    # scheduler) can drift a span ±20% on a shared box, and the minimum
    # is the standard low-variance estimator — applied identically to
    # both sides so the ratio stays honest
    spans = 3

    # ---- eager reference
    st = _onboard(_init(clients, cfg, mesh), n_clients)
    for _ in range(2):                       # steady-shape warm-up
        st, _ = engine.run_round(st)
    eager_s = float("inf")
    se = st
    for _ in range(spans):
        t0 = time.time()
        for _ in range(rounds):
            se, _ = engine.run_round(se)
        jax.block_until_ready(se.omega)
        eager_s = min(eager_s, time.time() - t0)

    # ---- fused scan: first call compiles, later calls are steady state
    st = _onboard(_init(clients, cfg, mesh), n_clients)
    t0 = time.time()
    s2 = engine.run_rounds(st, rounds)
    jax.block_until_ready(s2.omega)
    first_s = time.time() - t0
    scan_s = float("inf")
    for _ in range(spans):
        t0 = time.time()
        s2 = engine.run_rounds(s2, rounds)
        jax.block_until_ready(s2.omega)
        scan_s = min(scan_s, time.time() - t0)

    row = {
        "clients": n_clients, "rounds": rounds, "sample_rate": sample_rate,
        "cohort": int(np.ceil(sample_rate * n_clients)),
        "cohort_chunk": chunk, "n_per": n_per,
        "fused": fused, "dtype": dtype,
        "devices": 1 if mesh is None else int(mesh.devices.size),
        "eager_s": round(eager_s, 4),
        "eager_rounds_per_s": round(rounds / eager_s, 2),
        "scan_s": round(scan_s, 4),
        "scan_rounds_per_s": round(rounds / scan_s, 2),
        "first_compile_s": round(first_s - scan_s, 4),
        "speedup": round(eager_s / scan_s, 2),
    }
    if warm:
        # drop every in-process executable; the persistent cache (enabled
        # by main()) now serves the XLA compiles, so this first call pays
        # only trace + deserialize — the honest warm-restart compile tax
        jax.clear_caches()
        t0 = time.time()
        s3 = engine.run_rounds(s2, rounds)
        jax.block_until_ready(s3.omega)
        row["warm_first_compile_s"] = round(time.time() - t0 - scan_s, 4)
    return row


def run_async_point(n_clients: int, rounds: int, sample_rate: float,
                    n_per: int, staleness_cap: int) -> dict:
    """Async buffered rounds vs the eager sync round at one population
    size: every dispatch carries the saturating delay pattern
    (0, 1, …, cap, 0, 1, …) so flushes run full steady-state widths
    through the staleness-weighted merge, and the buffer sits at its
    occupancy bound — the honest per-round cost of buffering."""
    clients = _federation(n_clients, n_per)
    cohort = int(np.ceil(sample_rate * n_clients))
    delays = (np.arange(cohort) % (staleness_cap + 1)).astype(np.int64)
    cfg = _cfg(sample_rate, 0,
               async_cfg=engine.AsyncConfig(staleness_cap=staleness_cap))
    spans = 3

    # ---- eager sync reference (same shapes, same key chain)
    st = _onboard(_init(clients, cfg), n_clients)
    for _ in range(2):
        st, _ = engine.run_round(st)
    eager_s = float("inf")
    se = st
    for _ in range(spans):
        t0 = time.time()
        for _ in range(rounds):
            se, _ = engine.run_round(se)
        jax.block_until_ready(se.omega)
        eager_s = min(eager_s, time.time() - t0)

    # ---- async: warm until the delay pattern's widths lock in, then time
    st = _onboard(_init(clients, cfg), n_clients)
    for _ in range(staleness_cap + 3):
        st, _ = engine.run_round_async(st, delays=delays)
    async_s = float("inf")
    for _ in range(spans):
        t0 = time.time()
        for _ in range(rounds):
            st, _ = engine.run_round_async(st, delays=delays)
        jax.block_until_ready(st.omega)
        async_s = min(async_s, time.time() - t0)

    return {
        "clients": n_clients, "rounds": rounds, "sample_rate": sample_rate,
        "cohort": cohort, "n_per": n_per, "fused": False, "dtype": "float32",
        "devices": 1, "mode": "async", "staleness_cap": staleness_cap,
        "buffer_capacity": int(st.buffer.capacity),
        "eager_s": round(eager_s, 4),
        "eager_rounds_per_s": round(rounds / eager_s, 2),
        "async_s": round(async_s, 4),
        "async_rounds_per_s": round(rounds / async_s, 2),
        "async_overhead": round(async_s / eager_s, 2),
    }


def compile_sets(n_clients: int = 12, cycles: int = 3) -> dict:
    """Distinct-XLA-program counts per strategy over a churn timeline:
    ``cold`` is the full first-contact compile (init + first scanned
    span), ``cycle_i`` the programs added by the i-th join → train →
    leave → train cycle. Shape quantization makes the warm cycles 0
    for every strategy except stocfl's host bank rebuild."""
    from repro.analysis import sanitize
    from repro.models import simple as _simple

    eval_fn = jax.jit(lambda p, b: _simple.accuracy(p, b, TASK))
    extra = _federation(4, 32, seed=11)
    out = {}
    for name in ("stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"):
        kw = dict(tau=0.5, lam=0.05, lr=0.1, local_steps=2, sample_rate=0.5,
                  seed=0, rng_backend="device")
        if name == "stocfl":
            kw["cluster_backend"] = "device"
        if name == "cfl":
            kw.update(sample_rate=1.0, eps_rel=0.9, eps2=1e-4)
        cfg = engine.EngineConfig(**kw)
        clients = _federation(n_clients, 32)
        counts = {}
        with sanitize.compile_budget() as log:
            st = engine.init(name, LOSS,
                             _simple.init(jax.random.PRNGKey(0), TASK),
                             clients, cfg, eval_fn=eval_fn, arena=True)
            st = engine.run_rounds(st, 2)
        counts["cold"] = log.count
        for i in range(cycles):
            with sanitize.compile_budget() as log:
                st, cid = engine.join(st, extra[i])
                st = engine.run_rounds(st, 2)
                st = engine.leave(st, cid)
                st = engine.run_rounds(st, 2)
            counts[f"cycle_{i + 1}"] = log.count
        out[name] = counts
        print(json.dumps({name: counts}))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small populations, few rounds)")
    ap.add_argument("--out", default="BENCH_rounds.json")
    ap.add_argument("--rounds", type=int, default=0,
                    help="rounds per timed span (0 = per-size default)")
    ap.add_argument("--compile-sets", action="store_true",
                    help="measure per-strategy compile counts under churn "
                         "and merge them into --out (skips the timing sweep)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="sweep async buffered rounds (run_round_async) vs "
                         "the eager sync round over staleness caps and "
                         "MERGE the rows (mode=async) into --out")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="run the smoke points on an N-device client mesh "
                         "and MERGE the rows (devices=N) into --out; needs "
                         "N visible devices (REPRO_FORCE_HOST_DEVICES=8 to "
                         "force host devices on CPU — see docs/SHARDING.md)")
    args = ap.parse_args()

    if args.mesh:
        ndev = len(jax.devices())
        if args.mesh > ndev:
            raise SystemExit(
                f"--mesh {args.mesh} but only {ndev} device(s) visible; "
                f"set REPRO_FORCE_HOST_DEVICES={args.mesh} (read before "
                f"jax loads) to force host devices on CPU")
        from benchmarks.common import setup_cache
        from repro.launch.mesh import make_client_mesh
        setup_cache()
        mesh = make_client_mesh(args.mesh)
        points = [dict(n_clients=24, rounds=args.rounds or 10,
                       sample_rate=0.5, chunk=0, n_per=16),
                  dict(n_clients=48, rounds=args.rounds or 10,
                       sample_rate=0.25, chunk=0, n_per=16)]
        rows = []
        for p in points:
            r = run_point(mesh=mesh, **p)
            print(json.dumps(r))
            rows.append(r)
        # replace any stale rows for this (point, devices) combo, keep
        # the rest of the sweep untouched — the CI lane runs --mesh 1
        # and --mesh 4 back to back into the same file
        _merge_rows(args.out, rows)
        print(f"merged {len(rows)} mesh rows into {args.out}")
        return

    if args.async_mode:
        from benchmarks.common import setup_cache
        setup_cache()
        if args.smoke:
            points = [dict(n_clients=24, rounds=args.rounds or 10,
                           sample_rate=0.5, n_per=16, staleness_cap=c)
                      for c in (0, 4)] + \
                     [dict(n_clients=48, rounds=args.rounds or 10,
                           sample_rate=0.25, n_per=16, staleness_cap=4)]
        else:
            points = [dict(n_clients=400, rounds=args.rounds or 20,
                           sample_rate=0.1, n_per=64, staleness_cap=c)
                      for c in (0, 4, 8)] + \
                     [dict(n_clients=4000, rounds=args.rounds or 20,
                           sample_rate=0.05, n_per=32, staleness_cap=c)
                      for c in (0, 8)]
        rows = []
        for p in points:
            r = run_async_point(**p)
            print(json.dumps(r))
            rows.append(r)
        _merge_rows(args.out, rows)
        print(f"merged {len(rows)} async rows into {args.out}")
        return

    if args.compile_sets:
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"bench": "round_scan"}
        doc["compile_sets"] = {
            "task": "distinct XLA programs per strategy: cold start, then "
                    "join/train/leave/train churn cycles (12 clients, "
                    "2-round spans; counted by analysis.sanitize."
                    "compile_budget). Strategies run in-order in ONE "
                    "process, so programs shared across strategies (local "
                    "SGD, eval) are attributed to the first one measured "
                    "(stocfl); warm-cycle counts are the regression-gated "
                    "signal (tests/test_compile_budget.py)",
            "results": compile_sets()}
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.out}")
        return

    from benchmarks.common import setup_cache
    cache_dir = setup_cache()

    if args.smoke:
        points = [dict(n_clients=24, rounds=10, sample_rate=0.5,
                       chunk=0, n_per=16),
                  dict(n_clients=48, rounds=10, sample_rate=0.25,
                       chunk=0, n_per=16),
                  dict(n_clients=24, rounds=10, sample_rate=0.5,
                       chunk=0, n_per=16, fused=True, dtype="bfloat16")]
    else:
        points = [dict(n_clients=40, rounds=20, sample_rate=0.25,
                       chunk=0, n_per=64),
                  dict(n_clients=400, rounds=20, sample_rate=0.1,
                       chunk=0, n_per=64),
                  dict(n_clients=400, rounds=20, sample_rate=0.1,
                       chunk=0, n_per=64, fused=True),
                  dict(n_clients=400, rounds=20, sample_rate=0.1,
                       chunk=0, n_per=64, dtype="bfloat16"),
                  dict(n_clients=4000, rounds=20, sample_rate=0.05,
                       chunk=0, n_per=32)]
    results = []
    for p in points:
        if args.rounds:
            p["rounds"] = args.rounds
        r = run_point(warm=True, **p)
        print(json.dumps(r))
        results.append(r)

    doc = {"bench": "round_scan",
           "task": "stocfl round loop, scan (run_rounds) vs eager "
                   "(run_round), device arena+partition+rng in both; "
                   "fused/dtype rows sweep the flat prox kernel and "
                   "bf16 compute; warm_first_compile_s = first call "
                   "after jax.clear_caches() with the persistent "
                   "compilation cache serving",
           "compile_cache_dir": cache_dir,
           "platform": {"machine": platform.machine(),
                        "python": platform.python_version(),
                        "jax": jax.__version__,
                        "backend": jax.default_backend()},
           "results": results}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
