"""Kernel micro-benchmarks: us_per_call of the jnp reference path on CPU
(the Pallas kernels are TPU-target; interpret mode is not a timing proxy).
Derived: output checksums + allclose-vs-oracle status at bench shapes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.cosine_sim import cosine_sim
from repro.kernels.prox_update import prox_update_flat
from repro.kernels.ssm_scan import ssm_scan


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    # cosine: paper cross-device scale N=4800 clients, proj dim 2048
    x = jax.random.normal(key, (4800, 2048))
    f = jax.jit(lambda x: ops.pairwise_cosine(x, backend="jnp"))
    us = _time(f, x)
    got = cosine_sim(x[:64], bn=32, bk=256, interpret=True)
    ok = np.allclose(np.asarray(got), np.asarray(ref.cosine_sim_ref(x[:64])), atol=1e-4)
    rows.append(("kernel_cosine_4800x2048", us, f"allclose={ok}"))

    # prox update: 1.6M-param MLP flattened
    n = 1_640_000
    t, o, gt, go = (jax.random.normal(jax.random.fold_in(key, i), (n,)) for i in range(4))
    f = jax.jit(lambda *a: ref.prox_update_ref(*a, 0.1, 0.05))
    us = _time(f, t, o, gt, go)
    got = prox_update_flat(t[:4096], o[:4096], gt[:4096], go[:4096], 0.1, 0.05,
                           block=1024, interpret=True)
    want = ref.prox_update_ref(t[:4096], o[:4096], gt[:4096], go[:4096], 0.1, 0.05)
    ok = np.allclose(np.asarray(got[0]), np.asarray(want[0]), atol=1e-5)
    rows.append(("kernel_prox_1.6M", us, f"allclose={ok}"))

    # ssm scan: falcon-mamba-ish tile (B=2, S=512, D=256, N=16)
    dA = jax.nn.sigmoid(jax.random.normal(key, (2, 512, 256, 16)))
    dBx = jax.random.normal(jax.random.fold_in(key, 9), (2, 512, 256, 16)) * 0.1
    C = jax.random.normal(jax.random.fold_in(key, 10), (2, 512, 16))
    f = jax.jit(ref.ssm_scan_ref)
    us = _time(f, dA, dBx, C)
    got = ssm_scan(dA[:, :64, :32], dBx[:, :64, :32], C[:, :64], bd=16, chunk=16,
                   interpret=True)
    ok = np.allclose(np.asarray(got), np.asarray(ref.ssm_scan_ref(
        dA[:, :64, :32], dBx[:, :64, :32], C[:, :64])), atol=1e-4, rtol=1e-4)
    rows.append(("kernel_ssm_2x512x256x16", us, f"allclose={ok}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
