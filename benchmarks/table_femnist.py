"""Table 2 (FEMNIST) reproduction on the writer-style mixture: StoCFL τ
sweep vs IFCA/CFL/FedAvg. Paper claims: StoCFL best; discovers ~2 latent
clusters; robust across τ."""
from __future__ import annotations

from benchmarks.common import run_baseline, run_stocfl, to_dev
from repro.data import femnist_like


def run(n_clients=60, rounds=30, seed=1):
    clients, tc, tests = femnist_like(n_clients=n_clients, seed=seed)
    clients, tests = to_dev(clients, tests)
    rows = []
    for tau in [0.55, 0.60, 0.65]:
        s = run_stocfl(clients, tc, tests, rounds=rounds, tau=tau,
                       sample_rate=0.1, seed=seed)
        rows.append((f"femnist_stocfl_tau{tau}", s["us_per_round"],
                     f"acc={s['acc']:.4f};K={s['k']};ari={s['ari']:.3f}"))
    for algo in ["ifca", "cfl", "fedavg"]:
        b = run_baseline(algo, clients, tc, tests, rounds=rounds,
                         sample_rate=0.1, seed=seed)
        rows.append((f"femnist_{algo}", b["us_per_round"], f"acc={b['acc']:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
