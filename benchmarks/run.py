"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. us_per_call is the per-round (or
per-item) wall time of the measured computation on this host; derived
carries the paper-claim metrics (accuracy / ARI / cluster count / term
separations) EXPERIMENTS.md references.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1     # one suite
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (fig2_similarity, fig3_clustering, fig8_tau, kernels_bench,
                        table1_rotated, table2_shifted, table3_lambda,
                        table3b_lambda_transfer, table4_generalization,
                        table_femnist)
from benchmarks.common import emit

SUITES = {
    "fig2": fig2_similarity.run,
    "fig3": fig3_clustering.run,
    "fig8": fig8_tau.run,
    "table1": table1_rotated.run,
    "table2": table2_shifted.run,
    "table3": table3_lambda.run,
    "table3b": table3b_lambda_transfer.run,
    "femnist": table_femnist.run,
    "table4": table4_generalization.run,
    "kernels": kernels_bench.run,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    for name in wanted:
        try:
            emit(SUITES[name]())
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name},-1,ERROR={e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
