"""Table 1 reproduction: Rotated setting, StoCFL vs FedAvg/FedProx/Ditto/
IFCA at 10% and 100% client participation. Paper claim: StoCFL wins in
most cells and is robust to the sample rate."""
from __future__ import annotations

from benchmarks.common import run_baseline, run_stocfl, to_dev
from repro.data import rotated


def run(n_clients=48, rounds=30, seed=1):
    clients, tc, tests = rotated(n_clusters=4, n_clients=n_clients, seed=seed)
    clients, tests = to_dev(clients, tests)
    rows = []
    for rate, tag in [(0.1, "10pct"), (1.0, "100pct")]:
        s = run_stocfl(clients, tc, tests, rounds=rounds, sample_rate=rate, seed=seed)
        rows.append((f"table1_stocfl_{tag}", s["us_per_round"],
                     f"acc={s['acc']:.4f};ari={s['ari']:.3f};K={s['k']}"))
        for algo in ["fedavg", "fedprox", "ditto", "ifca"]:
            b = run_baseline(algo, clients, tc, tests, rounds=rounds,
                             sample_rate=rate, seed=seed)
            rows.append((f"table1_{algo}_{tag}", b["us_per_round"], f"acc={b['acc']:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
