"""Fig. 2 reproduction: Ψ cosine-similarity structure across the four
Non-IID skews. Derived metric: within-cluster minus between-cluster mean
cosine (paper shows visibly-blocked matrices; we report the separation)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import LOSS, init_params
from repro.core.extractor import make_extractor
from repro.data import hybrid, pathological, rotated, shifted
from repro.kernels import ops


def run(n_clients=24, seed=1):
    params = init_params(seed)
    ext = make_extractor(LOSS, params)
    rows = []
    for name, maker in [("pathological", pathological), ("rotated", rotated),
                        ("shifted", shifted), ("hybrid", hybrid)]:
        clients, tc, _ = maker(n_clients=n_clients, seed=seed)
        t0 = time.time()
        reps = jnp.stack([ext(jax.tree.map(jnp.asarray, c)) for c in clients])
        M = np.asarray(ops.pairwise_cosine(reps))
        us = (time.time() - t0) / n_clients * 1e6
        tc = np.array(tc)
        same = M[(tc[:, None] == tc[None, :]) & ~np.eye(len(tc), dtype=bool)].mean()
        diff = M[tc[:, None] != tc[None, :]].mean()
        rows.append((f"fig2_{name}", us,
                     f"within={same:.3f};between={diff:.3f};sep={same-diff:.3f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
