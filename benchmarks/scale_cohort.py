"""Scale trajectory bench: population × participation sweep on the cohort
substrate, arena vs legacy restack. Writes ``BENCH_scale.json``.

Sweeps clients ∈ {40, 400, 4000} × participation ∈ {0.1, 0.5, 1.0} of the
StoCFL round on the paper's synthetic MLP task, in two modes:

  arena   device-resident ClientArena + stacked ClusterBank: cohort data
          and cluster models are single gathers; cohorts above
          ``--chunk`` run in lax.map chunks (flat memory) — this is how
          the 4000-client, 100%-participation point fits and finishes.
  legacy  the arena-less fallback: per-round Python restack of cohort
          data AND per-client cluster-model stacking. (The server-side
          aggregation is the shared segment-sum path in BOTH modes — it
          is kept identical so the parity tests can assert bitwise
          equality — so the speedup isolates the gather/stack side.)
          Run only up to ``--legacy-max-cohort`` clients·participation
          (it is the thing being replaced; points above the cap are
          reported as skipped, not silently dropped).

The sweep is orchestration-honest: ``local_steps=1`` keeps the round in
the regime where the server's data/model movement — the part the arena
removes — is visible next to the (identical) client compute.

  PYTHONPATH=src python -m benchmarks.scale_cohort              # full sweep
  PYTHONPATH=src python -m benchmarks.scale_cohort --smoke      # CI-sized
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _federation(n_clients: int, n_per: int, seed: int = 0):
    clients, _, _ = rotated(n_clusters=4, n_clients=n_clients, n_per=n_per,
                            seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _cfg(participation: float, chunk: int,
         local_steps: int) -> engine.EngineConfig:
    return engine.EngineConfig(
        tau=0.5, lam=0.05, lr=0.1, local_steps=local_steps,
        sample_rate=participation, seed=0,
        # full-gradient Ψ is |θ|-dim; the JL sketch keeps the per-round
        # clustering state O(1024) per client at every population size
        # (1024 preserves the cosine gaps well enough that the partition
        # settles right after onboarding — smaller sketches keep merging
        # for several rounds, which is clustering noise, not round cost)
        project_dim=1024,
        cohort_chunk=chunk)


def _time_rounds(state, rounds: int, n_clients: int):
    """Measure steady-state rounds: one full-participation onboarding
    round first (observes every client, does all Ψ-merging, compiles the
    big-cohort path), one sampled round (compiles the steady shapes),
    then the timed rounds — so the metric is the per-round cost of a
    fully-onboarded federation, not jit warm-up or the one-time
    clustering transient."""
    t0 = time.time()
    state, _ = engine.run_round(state, np.arange(n_clients))
    onboard = time.time() - t0
    for _ in range(5):          # settle residual merges + the bounded set
        state, _ = engine.run_round(state)   # of cohort-spans-G shapes
    times = []
    for _ in range(rounds):
        t0 = time.time()
        state, _ = engine.run_round(state)
        jax.block_until_ready(state.omega)
        times.append(time.time() - t0)
    return state, float(np.median(times)), onboard


def run_point(clients, n_clients: int, participation: float, mode: str,
              chunk: int, rounds: int, local_steps: int) -> dict:
    cohort = max(int(round(participation * n_clients)), 1)
    eff_chunk = chunk if (mode == "arena" and cohort > chunk > 0) else 0
    cfg = _cfg(participation, eff_chunk, local_steps)
    t0 = time.time()
    st = engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, cfg, arena=(mode == "arena"))
    st, sec, onboard = _time_rounds(st, rounds, n_clients)
    return {"clients": n_clients, "participation": participation,
            "cohort": cohort, "mode": mode, "chunk": eff_chunk,
            "sec_per_round": round(sec, 4),
            "sec_onboard_round": round(onboard, 2),
            "sec_total": round(time.time() - t0, 2),
            "n_clusters": st.clusters.n_clusters(), "rounds": rounds}


def run(smoke: bool = False, chunk: int = 512, rounds: int = 3,
        n_per: int = 32, local_steps: int = 1,
        legacy_max_cohort: int = 400):
    populations = [40, 400] if smoke else [40, 400, 4000]
    participations = [0.1, 1.0] if smoke else [0.1, 0.5, 1.0]
    if smoke:
        rounds = min(rounds, 3)
    points, skipped = [], []
    for n in populations:
        clients = _federation(n, n_per)
        for p in participations:
            for mode in ("arena", "legacy"):
                cohort = max(int(round(p * n)), 1)
                if mode == "legacy" and cohort > legacy_max_cohort:
                    skipped.append({"clients": n, "participation": p,
                                    "mode": mode,
                                    "reason": f"cohort {cohort} > "
                                              f"--legacy-max-cohort "
                                              f"{legacy_max_cohort}"})
                    print(f"# skip clients={n} p={p} mode=legacy "
                          f"(cohort {cohort} over legacy cap)")
                    continue
                pt = run_point(clients, n, p, mode, chunk, rounds, local_steps)
                points.append(pt)
                print(f"# clients={n} p={p} mode={mode} chunk={pt['chunk']} "
                      f"sec/round={pt['sec_per_round']:.3f}")
    return points, skipped


def summarize(points) -> dict:
    by = {(p["clients"], p["participation"], p["mode"]): p["sec_per_round"]
          for p in points}
    out = {}
    for (n, part, mode), sec in sorted(by.items()):
        leg = by.get((n, part, "legacy"))
        if mode == "arena" and leg:
            out[f"speedup_{n}_p{part}"] = round(leg / sec, 2)
    n400 = [v for k, v in out.items() if k.startswith("speedup_400_")]
    if n400:
        out["speedup_400"] = round(max(n400), 2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (40/400 clients, <=2 rounds)")
    ap.add_argument("--chunk", type=int, default=512,
                    help="cohort_chunk for arena points with big cohorts")
    ap.add_argument("--rounds", type=int, default=5,
                    help="timed steady-state rounds (median reported)")
    ap.add_argument("--n-per", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--legacy-max-cohort", type=int, default=400,
                    help="largest cohort the legacy restack mode is run at")
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args()

    t0 = time.time()
    points, skipped = run(smoke=args.smoke, chunk=args.chunk,
                          rounds=args.rounds, n_per=args.n_per,
                          local_steps=args.local_steps,
                          legacy_max_cohort=args.legacy_max_cohort)
    doc = {
        "bench": "scale_cohort",
        "task": TASK.name,
        "n_per": args.n_per,
        "local_steps": args.local_steps,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "smoke": args.smoke,
        "wall_s": round(time.time() - t0, 1),
        "points": points,
        "skipped": skipped,
        "summary": summarize(points),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(json.dumps(doc["summary"], indent=1))
    print(f"# wrote {args.out} ({len(points)} points, "
          f"{len(skipped)} skipped) in {doc['wall_s']}s")


if __name__ == "__main__":
    main()
