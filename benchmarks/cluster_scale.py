"""Clustering-core scale bench: host ``ClusterState`` vs the device
union-find (``core.device_clustering``). Writes ``BENCH_cluster.json``.

Sweeps K̃ ∈ {64, 512, 4096} singleton clients drawn from ``--groups``
latent Non-IID distributions (the paper's 4-cluster settings scaled up)
and times the clustering step in its two regimes:

  merge   round-1 onboarding: all K̃ singletons observed, one
          ``merge_round`` collapses them to the latent groups. The host
          pays the O(#qualifying-pairs) Python union scan here — at
          K̃=4096 over 4 groups that is ~2M find/union iterations — while
          the device path is one jitted program (fused masked-cosine-τ
          candidates + O(log K̃) min-label propagation). This is the
          metric the ≥3×@4096 acceptance bar reads.
  scan    steady state: the partition has settled, a pass finds nothing
          to merge. Both paths are K̃-compact (the host slices its padded
          matrix, the device compacts live roots with a static-size
          nonzero), so this measures the floor, not the win.

``first_s`` is the first warm-up call (device: XLA compile; host: BLAS/
jit warm-up) — steady numbers exclude it; EXPERIMENTS.md explains how to
read the two apart. Timings are medians over ``--iters`` fresh
``copy()`` forks, so every merge iteration starts from the same
all-singleton state.

  PYTHONPATH=src python -m benchmarks.cluster_scale             # full sweep
  PYTHONPATH=src python -m benchmarks.cluster_scale --smoke     # CI-sized
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import ClusterState
from repro.core.device_clustering import DeviceClusters


def _reps(k: int, dim: int, groups: int, noise: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(groups, dim))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    reps = anchors[np.arange(k) % groups] + rng.normal(size=(k, dim)) * noise
    return (reps / np.linalg.norm(reps, axis=1, keepdims=True)
            ).astype(np.float32)


def bench_point(k: int, dim: int, groups: int, tau: float, noise: float,
                iters: int) -> dict:
    """One K̃ point, both backends, both regimes."""
    reps = _reps(k, dim, groups, noise)
    point: dict = {"k": k}
    for name, make in (("host", lambda: ClusterState(tau=tau)),
                       ("device", lambda: DeviceClusters(tau=tau,
                                                         capacity=k))):
        base = make()
        base.observe(range(k),
                     jnp.asarray(reps) if name == "device" else reps)
        t0 = time.time()
        warm = base.copy()
        warm.merge_round()
        first = time.time() - t0

        merge_ts = []
        for _ in range(iters):
            fork = base.copy()
            t0 = time.time()
            fork.merge_round()
            merge_ts.append(time.time() - t0)
        settled = fork
        scan_ts = []
        for _ in range(iters):
            t0 = time.time()
            merges = settled.merge_round()
            scan_ts.append(time.time() - t0)
            assert merges == [], "settled state merged again"
        point[name] = {"first_s": round(first, 4),
                       "merge_s": round(float(np.median(merge_ts)), 5),
                       "scan_s": round(float(np.median(scan_ts)), 5),
                       "k_after": settled.n_clusters()}
    assert point["host"]["k_after"] == point["device"]["k_after"] == groups
    point["merge_speedup"] = round(
        point["host"]["merge_s"] / max(point["device"]["merge_s"], 1e-9), 2)
    point["scan_speedup"] = round(
        point["host"]["scan_s"] / max(point["device"]["scan_s"], 1e-9), 2)
    return point


def main() -> None:
    """CLI entry: run the sweep and write the JSON artifact."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small K̃, fewer iters)")
    ap.add_argument("--out", default="BENCH_cluster.json")
    ap.add_argument("--dim", type=int, default=256,
                    help="Ψ representation dimension")
    ap.add_argument("--groups", type=int, default=4,
                    help="latent Non-IID distributions the singletons "
                         "collapse into")
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--iters", type=int, default=0,
                    help="timed repetitions per point (0 = auto)")
    args = ap.parse_args()

    ks = [16, 64, 128] if args.smoke else [64, 512, 4096]
    iters = args.iters or (3 if args.smoke else 5)
    out = {"meta": {"backend": jax.default_backend(),
                    "machine": platform.machine(),
                    "dim": args.dim, "groups": args.groups,
                    "tau": args.tau, "noise": args.noise,
                    "iters": iters, "smoke": bool(args.smoke)},
           "points": []}
    for k in ks:
        point = bench_point(k, args.dim, args.groups, args.tau,
                            args.noise, iters)
        out["points"].append(point)
        print(f"K={k:5d}  host merge {point['host']['merge_s']:.4f}s  "
              f"device merge {point['device']['merge_s']:.4f}s  "
              f"({point['merge_speedup']}x)  scan "
              f"{point['host']['scan_s']:.4f}s vs "
              f"{point['device']['scan_s']:.4f}s")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
