"""Table 4 reproduction: generalization to unseen clients. 70% of clients
participate in training; the held-out 30% are assigned clusters via §4.4
inference and evaluated. Paper claim: StoCFL's unparticipated accuracy
matches its participant accuracy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import EVAL, run_stocfl, to_dev
from repro.data import femnist_like


def run(n_clients=60, rounds=30, seed=1):
    clients, tc, tests = femnist_like(n_clients=n_clients, seed=seed)
    clients, tests = to_dev(clients, tests)
    n_train = int(0.7 * n_clients)
    out = run_stocfl(clients[:n_train], tc[:n_train], tests, rounds=rounds,
                     sample_rate=0.2, seed=seed)
    st = out["state"]

    # participants
    part_acc = out["acc"]
    # unparticipated: infer cluster from Ψ, evaluate that cluster's model
    from repro import engine
    accs = []
    for cid in range(n_train, n_clients):
        inf = engine.infer(st, clients[cid])
        accs.append(float(EVAL(inf["model"], tests[tc[cid]])))
    unpart_acc = float(np.mean(accs))
    return [("table4_generalization", out["us_per_round"],
             f"participant={part_acc:.4f};unparticipated={unpart_acc:.4f};K={out['k']}")]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
